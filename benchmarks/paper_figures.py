"""One benchmark per paper figure/table (§6).

Each function runs the relevant scenarios and returns rows of
(figure, scenario, metric, value) — ``run.py`` aggregates them into the CSV
consumed by EXPERIMENTS.md §Paper-validation.
"""
from __future__ import annotations

from functools import lru_cache

from repro.core.metrics import Metrics
from repro.sim import SCENARIOS, ScenarioConfig, run_scenario
from repro.sim.traces import TraceConfig, generate_trace, potential_counts

Row = tuple[str, str, str, float]


@lru_cache(maxsize=None)
def _run(name: str, n_frames: int, seed: int = 0) -> Metrics:
    base = SCENARIOS[name]
    cfg = ScenarioConfig(
        name=base.name, trace=base.trace, algorithm=base.algorithm,
        preemption=base.preemption, n_frames=n_frames, seed=seed)
    return run_scenario(cfg)


# Paper reference values for side-by-side comparison in the CSV.
PAPER = {
    ("fig2a", "UPS", "frame_completion_pct"): 50.0,
    ("fig2a", "UNPS", "frame_completion_pct"): 45.0,
    ("fig2a", "WPS_4", "frame_completion_pct"): 32.4,
    ("fig2a", "WNPS_4", "frame_completion_pct"): 29.36,
    ("fig2a", "DPW", "frame_completion_pct"): 8.96,
    ("fig2a", "DNPW", "frame_completion_pct"): 5.64,
    ("fig2a", "CPW", "frame_completion_pct"): 9.65,
    ("fig2a", "CNPW", "frame_completion_pct"): 9.23,
    ("fig3", "UPS", "hp_completion_pct"): 99.0,
    ("fig3", "UNPS", "hp_completion_pct"): 80.0,
    ("fig3", "WNPS_4", "hp_completion_pct"): 72.1,
    ("fig3", "CNPW", "hp_completion_pct"): 89.56,
    ("fig3", "DNPW", "hp_completion_pct"): 76.75,
    ("fig4", "WPS_4", "lp_completion_pct"): 51.73,
    ("fig4", "WNPS_4", "lp_completion_pct"): 63.31,
    ("fig4", "CPW", "lp_completion_pct"): 15.65,
    ("fig4", "CNPW", "lp_completion_pct"): 13.76,
    ("fig4", "DPW", "lp_completion_pct"): 14.20,
    ("fig4", "DNPW", "lp_completion_pct"): 11.36,
    ("fig4", "WPS_1", "lp_completion_pct"): 71.71,
    ("fig4", "WPS_2", "lp_completion_pct"): 72.07,
    ("fig4", "WPS_3", "lp_completion_pct"): 60.78,
    ("table2", "UPS", "lp_generated"): 8640,
    ("table2", "UNPS", "lp_generated"): 6961,
    ("table2", "WPS_4", "lp_generated"): 13941,
    ("table2", "WNPS_4", "lp_generated"): 9966,
    ("table2", "DPW", "lp_generated"): 13935,
    ("table2", "CPW", "lp_generated"): 13800,
}


def fig2_frame_completion(n_frames: int) -> list[Row]:
    rows = []
    for name in ("UPS", "UNPS", "WPS_4", "WNPS_4", "DPW", "DNPW", "CPW",
                 "CNPW"):
        m = _run(name, n_frames)
        rows.append(("fig2a", name, "frame_completion_pct",
                     m.pct(m.frames_completed, m.frames_total)))
    for name in ("WPS_1", "WPS_2", "WPS_3", "WPS_4"):
        m = _run(name, n_frames)
        rows.append(("fig2b", name, "frame_completion_pct",
                     m.pct(m.frames_completed, m.frames_total)))
    return rows


def fig3_hp_completion(n_frames: int) -> list[Row]:
    rows = []
    for name in ("UPS", "UNPS", "WPS_4", "WNPS_4", "DPW", "DNPW", "CPW",
                 "CNPW"):
        m = _run(name, n_frames)
        rows.append(("fig3", name, "hp_completion_pct",
                     m.pct(m.hp_completed, m.hp_generated)))
        rows.append(("fig3", name, "hp_via_preemption_pct",
                     m.pct(m.hp_completed_via_preemption, m.hp_generated)))
    return rows


def fig4_6_lp_completion(n_frames: int) -> list[Row]:
    rows = []
    for name in ("UPS", "UNPS", "WPS_1", "WPS_2", "WPS_3", "WPS_4",
                 "WNPS_4", "DPW", "DNPW", "CPW", "CNPW"):
        m = _run(name, n_frames)
        rows.append(("fig4", name, "lp_completion_pct",
                     m.pct(m.lp_completed, m.lp_generated)))
        rows.append(("fig5", name, "lp_per_request_completion_pct",
                     100.0 * sum(m.lp_request_fractions)
                     / max(len(m.lp_request_fractions), 1)))
        rows.append(("fig6", name, "lp_offloaded_completion_pct",
                     m.pct(m.lp_offloaded_completed, m.lp_offloaded)))
    return rows


def fig7_preempted_config(n_frames: int) -> list[Row]:
    rows = []
    for name in ("UPS", "WPS_1", "WPS_2", "WPS_3", "WPS_4", "DPW", "CPW"):
        m = _run(name, n_frames)
        total = max(m.preemptions, 1)
        rows.append(("fig7", name, "preempted_2core_pct",
                     100.0 * m.preempted_by_cores.get(2, 0) / total))
        rows.append(("fig7", name, "preempted_4core_pct",
                     100.0 * m.preempted_by_cores.get(4, 0) / total))
    return rows


def fig8_core_allocation(n_frames: int) -> list[Row]:
    rows = []
    for name in ("WPS_4", "WNPS_4", "DPW", "CPW"):
        m = _run(name, n_frames)
        for cores in (2, 4):
            rows.append(("fig8", name, f"core{cores}_local",
                         float(m.core_alloc_local.get(cores, 0))))
            rows.append(("fig8", name, f"core{cores}_offloaded",
                         float(m.core_alloc_offloaded.get(cores, 0))))
    return rows


def fig9_10_scheduler_times(n_frames: int) -> list[Row]:
    rows = []
    for name in ("UPS", "UNPS", "WPS_1", "WPS_2", "WPS_3", "WPS_4",
                 "WNPS_4"):
        m = _run(name, n_frames)
        s = m.summary()
        rows.append(("fig9", name, "t_hp_initial_ms", s["t_hp_initial_ms"]))
        rows.append(("fig9", name, "t_hp_preempt_ms", s["t_hp_preempt_ms"]))
        rows.append(("fig10", name, "t_lp_alloc_ms", s["t_lp_alloc_ms"]))
        rows.append(("fig10", name, "t_realloc_ms", s["t_realloc_ms"]))
    return rows


def table2_lp_generated(n_frames: int) -> list[Row]:
    rows = []
    for name in ("UPS", "UNPS", "WPS_1", "WPS_2", "WPS_3", "WPS_4",
                 "WNPS_4", "CPW", "CNPW", "DPW", "DNPW"):
        m = _run(name, n_frames)
        rows.append(("table2", name, "lp_generated", float(m.lp_generated)))
    return rows


def table3_reallocation(n_frames: int) -> list[Row]:
    rows = []
    for name in ("UPS", "WPS_1", "WPS_2", "WPS_3", "WPS_4", "DPW"):
        m = _run(name, n_frames)
        rows.append(("table3", name, "realloc_failure",
                     float(m.realloc_failure)))
        rows.append(("table3", name, "realloc_success",
                     float(m.realloc_success)))
    return rows


def table4_potential_tasks(n_frames: int) -> list[Row]:
    rows = []
    for trace in ("uniform", "weighted_1", "weighted_2", "weighted_3",
                  "weighted_4"):
        tr = generate_trace(TraceConfig(trace, n_frames=n_frames))
        c = potential_counts(tr)
        rows.append(("table4", trace, "potential_low_priority",
                     float(c["potential_low_priority"])))
        rows.append(("table4", trace, "potential_high_priority",
                     float(c["potential_high_priority"])))
    return rows


ALL_FIGURES = [
    fig2_frame_completion,
    fig3_hp_completion,
    fig4_6_lp_completion,
    fig7_preempted_config,
    fig8_core_allocation,
    fig9_10_scheduler_times,
    table2_lp_generated,
    table3_reallocation,
    table4_potential_tasks,
]
