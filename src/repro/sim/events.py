"""Minimal discrete-event engine (heap of timestamped callbacks)."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional


class Event:
    __slots__ = ("time", "seq", "fn", "cancelled")

    def __init__(self, time: float, seq: int, fn: Callable[[], None]) -> None:
        self.time = time
        self.seq = seq
        self.fn = fn
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


class EventQueue:
    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = itertools.count()
        self.now = 0.0

    def push(self, time: float, fn: Callable[[], None]) -> Event:
        if time < self.now:
            time = self.now
        ev = Event(time, next(self._seq), fn)
        heapq.heappush(self._heap, ev)
        return ev

    def run(self, until: Optional[float] = None) -> None:
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            if until is not None and ev.time > until:
                heapq.heappush(self._heap, ev)   # keep it for the next run()
                self.now = until
                return
            self.now = ev.time
            ev.fn()
