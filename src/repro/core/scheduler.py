"""The paper's two scheduling algorithms (§4).

High-priority allocation: local-only, single-core, allocated at arrival time;
optionally backed by the deadline-aware preemption mechanism.

Low-priority allocation: offloadable, multi-configuration (2/4-core horizontal
partitioning), searching over the completion time-points of already-allocated
tasks up to the request deadline, with partial allocation, even spreading and
a core-upgrade pass.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Callable, Optional

from .calendar import NetworkState, Reservation
from .metrics import Metrics
from .network import NetworkConfig
from .task import LowPriorityRequest, Priority, Task, TaskState


@dataclass
class Allocation:
    """A committed placement decision for a single task."""

    task: Task
    device: int
    t_start: float
    t_end: float                       # end of reserved slot (incl. padding)
    cores: int
    offloaded: bool
    link_slots: list[Reservation] = field(default_factory=list)


@dataclass
class HPResult:
    success: bool
    allocation: Optional[Allocation] = None
    preempted: list[Task] = field(default_factory=list)
    reallocations: list[Allocation] = field(default_factory=list)


@dataclass
class LPResult:
    allocations: list[Allocation] = field(default_factory=list)
    failed: list[Task] = field(default_factory=list)


class PreemptionAwareScheduler:
    """Controller-side scheduler over the time-slotted network state."""

    def __init__(
        self,
        state: NetworkState,
        net: NetworkConfig,
        preemption: bool = True,
        metrics: Optional[Metrics] = None,
        on_preempt: Optional[Callable[[Task], None]] = None,
        victim_policy: str = "farthest_deadline",
    ) -> None:
        self.state = state
        self.net = net
        self.preemption = preemption
        self.metrics = metrics if metrics is not None else Metrics()
        # Callback into the runtime so a running victim is actually stopped.
        self.on_preempt = on_preempt
        # Victim selection among conflicting LP reservations:
        #   "farthest_deadline"  the paper's §4 rule.
        #   "weakest_set"        the paper's §8 future-work proposal
        #                        (beyond-paper): prefer a victim whose request
        #                        set is least likely to complete anyway —
        #                        fewest healthy siblings — so preemption
        #                        destroys the least prospective frame value;
        #                        tie-break by farthest deadline.
        if victim_policy not in ("farthest_deadline", "weakest_set"):
            raise ValueError(victim_policy)
        self.victim_policy = victim_policy
        self._requests: dict[int, LowPriorityRequest] = {}

    # ------------------------------------------------------------------ #
    # High-priority algorithm                                            #
    # ------------------------------------------------------------------ #
    def allocate_high_priority(self, task: Task, now: float) -> HPResult:
        t_wall = _time.perf_counter()
        self.state.gc(now)
        result = self._hp_inner(task, now)
        elapsed = _time.perf_counter() - t_wall
        if result.preempted:
            self.metrics.t_hp_preempt.append(elapsed)
        else:
            self.metrics.t_hp_initial.append(elapsed)
        return result

    def _hp_inner(self, task: Task, now: float) -> HPResult:
        net, link = self.net, self.state.link
        dev = self.state.devices[task.source_device]
        msg_dur = net.slot(net.msg.hp_alloc)

        def placement():
            """(msg_t1, t1, t2) for the earliest feasible window, or None if
            the deadline can't be met.  Recomputed after every preemption —
            each preempt message occupies the link and pushes the allocation
            message (and hence the processing window) later."""
            msg_t1 = link.earliest_slot(msg_dur, now)
            arrival = msg_t1 + msg_dur
            if arrival + net.t_hp > task.deadline:
                return None
            return msg_t1, arrival, arrival + net.hp_slot_time

        plan = placement()
        if plan is None:
            return HPResult(False)          # can't meet the deadline at all
        msg_t1, t1, t2 = plan

        if dev.fits(t1, t2, 1):
            return HPResult(True, self._commit_hp(task, msg_t1, msg_dur, t1, t2))

        if not self.preemption:
            return HPResult(False)

        # 3. preemption: evict conflicting LP tasks, farthest deadline first
        preempted: list[Task] = []
        while not dev.fits(t1, t2, 1):
            conflicts = [
                r
                for r in dev.reservations()
                if r.overlaps(t1, t2)
                and isinstance(r.tag, Task)
                and r.tag.priority == Priority.LOW
            ]
            if not conflicts:
                break
            victim_res = min(conflicts, key=self._victim_key)
            victim: Task = victim_res.tag
            dev.release(victim)
            victim.state = TaskState.PREEMPTED
            victim.preempt_count += 1
            self.metrics.preemptions += 1
            self.metrics.preempted_by_cores[victim_res.amount] += 1
            # preemption message to the executing device
            pre_dur = net.slot(net.msg.preempt)
            link.reserve_earliest(pre_dur, now, ("preempt", victim.task_id))
            if self.on_preempt is not None:
                self.on_preempt(victim)
            preempted.append(victim)
            plan = placement()              # link moved; re-derive the window
            if plan is None:
                return HPResult(False, preempted=preempted)
            msg_t1, t1, t2 = plan

        if not dev.fits(t1, t2, 1):
            return HPResult(False, preempted=preempted)

        alloc = self._commit_hp(task, msg_t1, msg_dur, t1, t2)

        # 4. attempt to reallocate every victim before its deadline
        reallocs: list[Allocation] = []
        for victim in preempted:
            r_wall = _time.perf_counter()
            re = self._allocate_lp_task(victim, now, victim.deadline)
            self.metrics.t_realloc.append(_time.perf_counter() - r_wall)
            if re is not None:
                victim.state = TaskState.ALLOCATED
                self.metrics.realloc_success += 1
                reallocs.append(re)
            else:
                victim.state = TaskState.FAILED
                self.metrics.realloc_failure += 1
        return HPResult(True, alloc, preempted, reallocs)

    def _victim_key(self, r: Reservation):
        """Smaller = preferred victim (used with min())."""
        task: Task = r.tag
        if self.victim_policy == "weakest_set":
            return (self._set_health(task), -task.deadline)
        return (-task.deadline,)

    def _set_health(self, task: Task) -> float:
        """Fraction of the task's request set still on track to complete."""
        req = (self._requests.get(task.request_id)
               if task.request_id is not None else None)
        if req is None or not req.tasks:
            return 1.0
        good = sum(
            1 for t in req.tasks
            if t.state in (TaskState.COMPLETED, TaskState.ALLOCATED,
                           TaskState.RUNNING)
        )
        return good / len(req.tasks)

    def _commit_hp(
        self, task: Task, msg_t1: float, msg_dur: float, t1: float, t2: float
    ) -> Allocation:
        net, link = self.net, self.state.link
        dev = self.state.devices[task.source_device]
        slots = [link.reserve(msg_t1, msg_t1 + msg_dur, ("hp_alloc", task.task_id))]
        dev.reserve(t1, t2, 1, task)
        upd_dur = net.slot(net.msg.state_update)
        slots.append(link.reserve_earliest(upd_dur, t2, ("update", task.task_id)))
        task.state = TaskState.ALLOCATED
        task.device, task.cores = task.source_device, 1
        task.t_start, task.t_end, task.offloaded = t1, t2, False
        return Allocation(task, task.source_device, t1, t2, 1, False, slots)

    # ------------------------------------------------------------------ #
    # Low-priority algorithm                                             #
    # ------------------------------------------------------------------ #
    def allocate_low_priority(self, request: LowPriorityRequest, now: float) -> LPResult:
        t_wall = _time.perf_counter()
        self.state.gc(now)
        self._requests[request.request_id] = request     # set-health registry
        deadline = request.deadline
        unallocated = [t for t in request.tasks if t.state == TaskState.PENDING]
        result = LPResult()

        time_points = [now] + self.state.completion_times(now, deadline)
        for tp in time_points:
            if not unallocated:
                break
            for task in list(unallocated):
                alloc = self._allocate_lp_task(task, tp, deadline)
                if alloc is not None:
                    unallocated.remove(task)
                    result.allocations.append(alloc)
            # upgrade pass: try to give every allocated task more cores
            for alloc in result.allocations:
                self._try_upgrade(alloc)

        result.failed = unallocated
        for t in unallocated:
            t.state = TaskState.FAILED
        self.metrics.t_lp_alloc.append(_time.perf_counter() - t_wall)
        return result

    def reallocate(self, task: Task, now: float) -> Optional[Allocation]:
        """Public reallocation entry (used by runtimes on external preemption)."""
        r_wall = _time.perf_counter()
        alloc = self._allocate_lp_task(task, now, task.deadline)
        self.metrics.t_realloc.append(_time.perf_counter() - r_wall)
        if alloc is not None:
            task.state = TaskState.ALLOCATED
            self.metrics.realloc_success += 1
        else:
            task.state = TaskState.FAILED
            self.metrics.realloc_failure += 1
        return alloc

    def _allocate_lp_task(
        self, task: Task, tp: float, deadline: float
    ) -> Optional[Allocation]:
        """Partial allocation of one task at the minimum viable config (§4)."""
        net, link = self.net, self.state.link
        msg_dur = net.slot(net.msg.lp_alloc)
        msg_t1 = link.earliest_slot(msg_dur, tp)
        arrival = msg_t1 + msg_dur
        cores = net.lp_core_options[0]          # minimum viable config
        proc = net.lp_slot_time(cores)
        xfer_dur = net.slot(net.msg.input_transfer)

        # candidate order: source device first, then spread evenly by load
        source = task.source_device
        others = sorted(
            (d for d in self.state.devices if d.device != source),
            key=lambda d: (d.load(arrival, deadline), d.device),
        )
        for dev in [self.state.devices[source]] + others:
            offloaded = dev.device != source
            if offloaded:
                xfer_t1 = link.earliest_slot(xfer_dur, arrival)
                t1 = xfer_t1 + xfer_dur
            else:
                xfer_t1 = 0.0
                t1 = arrival
            t2 = t1 + proc
            if t2 > deadline:
                continue
            if not dev.fits(t1, t2, cores):
                continue
            # commit
            slots = [link.reserve(msg_t1, msg_t1 + msg_dur, ("lp_alloc", task.task_id))]
            if offloaded:
                slots.append(
                    link.reserve(xfer_t1, xfer_t1 + xfer_dur, ("xfer", task.task_id))
                )
            dev.reserve(t1, t2, cores, task)
            upd_dur = net.slot(net.msg.state_update)
            slots.append(link.reserve_earliest(upd_dur, t2, ("update", task.task_id)))
            task.state = TaskState.ALLOCATED
            task.device, task.cores = dev.device, cores
            task.t_start, task.t_end, task.offloaded = t1, t2, offloaded
            return Allocation(task, dev.device, t1, t2, cores, offloaded, slots)
        return None

    def _try_upgrade(self, alloc: Allocation) -> bool:
        """Improve an allocation by raising its core configuration (§4)."""
        net = self.net
        options = [c for c in net.lp_core_options if c > alloc.cores]
        if not options:
            return False
        dev = self.state.devices[alloc.device]
        res = dev.get(alloc.task)
        if res is None:
            return False
        for cores in reversed(options):          # largest improvement first
            t2 = alloc.t_start + net.lp_slot_time(cores)
            dev.release(alloc.task)
            if t2 <= alloc.task.deadline and dev.fits(alloc.t_start, t2, cores):
                dev.reserve(alloc.t_start, t2, cores, alloc.task)
                alloc.cores, alloc.t_end = cores, t2
                alloc.task.cores, alloc.task.t_end = cores, t2
                return True
            dev.reserve(res.t1, res.t2, res.amount, alloc.task)
        return False
