"""Regression tests for the set-iteration fixes the ``determinism-set-iter``
lint rule surfaced (this PR): the three decision-path loops that iterated
raw sets now settle in a pinned order, and the lint plane keeps them that
way.

* ``WorkstealingPolicy.finalize`` settles stranded victims in ascending
  ``task_id`` order (was: CPython set order over ``Task`` objects);
* ``PreemptionAwareScheduler.allocate_low_priority_batch`` runs its upgrade
  pass in ascending request-index order (was: set order of ``progressed``
  — upgrades shrink reservations, so cross-request order changes what
  later upgrades see);
* ``NetworkState.gc`` collects expired devices in ascending index order.
"""
from pathlib import Path

from repro.analysis import SetIterRule, run_analysis
from repro.core.calendar import NetworkState
from repro.core.network import NetworkConfig
from repro.core.scheduler import PreemptionAwareScheduler
from repro.core.task import (LowPriorityRequest, Priority, Task, TaskState,
                             reset_id_counters)
from repro.core.workstealer import WorkstealingPolicy

SRC = Path(__file__).parent.parent / "src"


# --------------------------------------------------------------------------- #
# workstealer.finalize: settle order is ascending task_id                     #
# --------------------------------------------------------------------------- #
class _Recorder:
    """Stands in for a preempt-pending Task; logs when it is settled."""

    def __init__(self, task_id, log):
        self.task_id = task_id
        self._log = log
        self._state = TaskState.PREEMPTED

    @property
    def state(self):
        return self._state

    @state.setter
    def state(self, value):
        self._state = value
        self._log.append(self.task_id)


def test_finalize_settles_pending_victims_in_task_id_order():
    ws = WorkstealingPolicy(2, NetworkConfig(), central=True)
    log = []
    ids = [937, 3, 512, 88, 7001]      # colliding int-set buckets
    for tid in ids:
        ws._preempt_pending.add(_Recorder(tid, log))
    ws.finalize(0.0)
    assert log == sorted(ids)
    assert ws.metrics.realloc_failure == len(ids)
    assert not ws._preempt_pending


# --------------------------------------------------------------------------- #
# scheduler batch upgrade pass: replay-identical                              #
# --------------------------------------------------------------------------- #
def _run_contended_batch():
    reset_id_counters()
    state = NetworkState(2)
    sched = PreemptionAwareScheduler(state, NetworkConfig())
    reqs = []
    for i in range(6):
        req = LowPriorityRequest(source_device=i % 2,
                                 deadline=20.0 + 5.0 * i,
                                 frame_id=i, n_tasks=3)
        req.make_tasks()
        reqs.append(req)
    results = sched.allocate_low_priority_batch(reqs, 0.0)
    return [
        sorted((a.task.task_id, a.device, a.cores,
                round(a.t_start, 9), round(a.t_end, 9))
               for a in res.allocations)
        + sorted(t.task_id for t in res.failed)
        for res in results
    ]


def test_batch_upgrade_pass_is_replay_identical():
    first = _run_contended_batch()
    assert any(row for row in first), "scenario admitted nothing"
    assert first == _run_contended_batch()


# --------------------------------------------------------------------------- #
# NetworkState.gc: all expired devices collected, heap re-registered          #
# --------------------------------------------------------------------------- #
def test_networkstate_gc_collects_every_expired_device():
    state = NetworkState(4)
    for d in (3, 1, 2):                # deliberately not in index order
        t = Task(priority=Priority.LOW, source_device=d,
                 deadline=50.0, frame_id=d)
        state.devices[d].reserve(0.0, 1.0 + d, 1, t)
        keeper = Task(priority=Priority.LOW, source_device=d,
                      deadline=80.0, frame_id=10 + d)
        state.devices[d].reserve(0.0, 60.0, 1, keeper)
    assert state.total_allocated_tasks() == 6
    state.gc(10.0)                     # all short reservations expired
    assert state.total_allocated_tasks() == 3
    # every surviving device is re-registered on the expiry heap
    # (duplicate entries are fine — gc dedupes them via ``seen`` on pop)
    assert {idx for _t, idx in state._expiry} == {1, 2, 3}
    assert all(exp > 10.0 for exp, _idx in state._expiry)


# --------------------------------------------------------------------------- #
# and the lint plane holds the line                                           #
# --------------------------------------------------------------------------- #
def test_fixed_files_have_no_unbaselined_set_iter_findings():
    files = [SRC / "repro/core/scheduler.py",
             SRC / "repro/core/workstealer.py",
             SRC / "repro/core/calendar.py",
             SRC / "repro/core/oracle.py"]
    report = run_analysis(SRC, rules=[SetIterRule()], files=files)
    assert not report.findings, report.findings
