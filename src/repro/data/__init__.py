from .pipeline import DataConfig, input_specs, text_len, train_batches  # noqa: F401
