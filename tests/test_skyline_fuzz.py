"""Seeded fuzz differential suite for the array-backed skyline calendars.

Random ``add`` / ``reserve`` / ``cancel`` / ``truncate`` / ``gc`` / query
sequences are driven through the NumPy gap-buffer skyline and, side by
side, through an independent oracle — the frozen seed implementation
(``calendar_reference``) where one exists, or a brute-force interval sweep
re-implementing the pre-rewrite walk semantics for the queries the seed
never had (``first_fit``).  Answers must match at EVERY step, so a single
bad splice in the mutation log, gap shifting, coalescing or prefix-sum
bookkeeping fails loudly with the seed that reproduces it.

No hypothesis dependency: plain seeded ``random`` sweeps, deterministic
corpus (the container image does not ship hypothesis).

Set ``REPRO_FUZZ_SEEDS=<k>`` to multiply every seed count by ``k`` (the
CI deep-fuzz job runs with a large multiplier; tier-1 defaults are
unchanged at ``k=1``).
"""
import math
import os
import random

import pytest

from repro.core.calendar import (
    EPS,
    DeviceCalendar,
    LinkCalendar,
    NetworkState,
    _StepFn,
)
from repro.core.calendar_reference import (
    ReferenceDeviceCalendar,
    ReferenceLinkCalendar,
)

#: Seed-count multiplier (REPRO_FUZZ_SEEDS env var; default x1 = tier-1).
FUZZ_SCALE = max(1, int(os.environ.get("REPRO_FUZZ_SEEDS", "1") or "1"))

_INF = math.inf


# --------------------------------------------------------------------- #
# Brute-force oracle for the raw step function                          #
# --------------------------------------------------------------------- #
class BruteStep:
    """Interval-list oracle with the exact pre-rewrite query semantics."""

    def __init__(self):
        self.ivals = []                     # (t1, t2, amount), t1 pre-clamped
        self.floor = -_INF

    def add(self, t1, t2, amount):
        if t1 < self.floor:
            t1 = self.floor
        if t2 <= t1:
            return
        self.ivals.append((t1, t2, amount))

    def gc(self, now):
        if now > self.floor:
            self.floor = now

    def segments(self):
        """Coalesced (times, vals) with the -inf sentinel, like _StepFn."""
        pts = sorted({t for iv in self.ivals for t in iv[:2]})
        times, vals = [-_INF], [0]
        for p in pts:
            v = sum(a for t1, t2, a in self.ivals if t1 <= p < t2)
            if v != vals[-1] or p == times[-1]:
                times.append(p)
                vals.append(v)
            else:
                # breakpoint with unchanged value: coalesced away
                continue
        return times, vals

    def usage(self, x):
        return sum(a for t1, t2, a in self.ivals if t1 <= x < t2)

    def max_over(self, a, b):
        if b <= a:
            return 0
        cands = [a] + [t for iv in self.ivals for t in iv[:2] if a < t < b]
        return max(self.usage(x) for x in cands)

    def integral(self, a, b):
        if b <= a:
            return 0.0
        return sum(v * (min(t2, b) - max(t1, a))
                   for t1, t2, v in self.ivals
                   if t1 < b and t2 > a)

    def first_fit(self, duration, not_before, limit):
        """The seed's segment walk, verbatim, over the brute segments."""
        times, vals = self.segments()
        t = not_before if not_before > self.floor else self.floor
        i = 0
        while i + 1 < len(times) and times[i + 1] <= t:
            i += 1
        n = len(times)
        cand = t
        while True:
            if vals[i] > limit:
                i += 1
                if i >= n:
                    return cand
                cand = times[i]
            else:
                seg_end = times[i + 1] if i + 1 < n else _INF
                if seg_end - cand >= duration - EPS:
                    return cand
                i += 1


@pytest.mark.parametrize("seed", range(30 * FUZZ_SCALE))
def test_stepfn_fuzz_vs_brute(seed):
    rng = random.Random(1000 + seed)
    sf = _StepFn()
    oracle = BruteStep()
    now = 0.0
    for op in range(120):
        c = rng.random()
        if c < 0.55:
            t1 = now + rng.uniform(0, 25)
            dur = rng.uniform(0.01, 8)
            amount = rng.choice([1, 2, 4, -1, -2])
            sf.add(t1, t1 + dur, amount)
            oracle.add(t1, t1 + dur, amount)
        elif c < 0.70 and rng.random() < 0.5:
            # burst without intervening queries: exercises the vectorized
            # batch rebuild instead of the in-place splice
            for _ in range(rng.randint(10, 25)):
                t1 = now + rng.uniform(0, 25)
                dur = rng.uniform(0.01, 6)
                sf.add(t1, t1 + dur, 1)
                oracle.add(t1, t1 + dur, 1)
        elif c < 0.80:
            now += rng.uniform(0, 6)
            sf.gc(now)
            oracle.gc(now)
        # queries at/after the gc horizon, every step
        a = now + rng.uniform(0, 30)
        b = a + rng.uniform(0.01, 15)
        assert sf.max_over(a, b) == oracle.max_over(a, b)
        assert sf.exceeds(a, b, 2) == (oracle.max_over(a, b) > 2)
        assert sf.integral(a, b) == pytest.approx(oracle.integral(a, b),
                                                  abs=1e-6)
        dur = rng.uniform(0.05, 5)
        limit = rng.choice([0, 1, 2, 3])
        assert sf.first_fit(dur, a, limit) == pytest.approx(
            oracle.first_fit(dur, a, limit), abs=0.0)
        # structural invariants of the gap buffer
        t, v = sf._view()
        assert t[0] == -_INF and v[-1] == 0
        assert all(t[i] < t[i + 1] for i in range(len(t) - 1))
        assert all(v[i] != v[i + 1] for i in range(len(v) - 1))


@pytest.mark.parametrize("seed", range(25 * FUZZ_SCALE))
def test_device_calendar_fuzz(seed):
    """Longer, meaner sequences than test_calendar_equivalence: tag
    re-reservation, truncation churn, interleaved gc, plus the queries the
    reference never had (earliest_fit, checked against the brute walk)."""
    rng = random.Random(7000 + seed)
    new = DeviceCalendar(0, 4)
    ref = ReferenceDeviceCalendar(0, 4)
    oracle = BruteStep()
    live = []
    now = 0.0
    for op in range(150):
        c = rng.random()
        if c < 0.40 or not live:
            t1 = now + rng.uniform(0, 40)
            dur = rng.uniform(0.05, 12)
            cores = rng.choice([1, 2, 4])
            tag = (seed, op) if rng.random() < 0.9 or not live \
                else rng.choice(live)          # sometimes replace a tag
            prev = ref.get(tag)
            if prev is not None:
                oracle.add(prev.t1, prev.t2, -prev.amount)
                live.remove(tag)
            new.reserve(t1, t1 + dur, cores, tag)
            ref.reserve(t1, t1 + dur, cores, tag)
            oracle.add(t1, t1 + dur, cores)
            live.append(tag)
        elif c < 0.55:
            tag = live.pop(rng.randrange(len(live)))
            r = ref.get(tag)
            oracle.add(r.t1, r.t2, -r.amount)
            assert (new.release(tag) is None) == (ref.release(tag) is None)
        elif c < 0.70:
            tag = rng.choice(live)
            r = ref.get(tag)
            t_end = rng.uniform(r.t1 - 1.0, r.t2 + 1.0)
            if t_end < r.t2:
                oracle.add(max(t_end, r.t1), r.t2, -r.amount)
            new.truncate(tag, t_end)
            ref.truncate(tag, t_end)
            if ref.get(tag) is None:
                live.remove(tag)
        elif c < 0.82:
            now += rng.uniform(0, 12)
            new.gc(now)
            ref.gc(now)
            oracle.gc(now)
            live = [t for t in live if ref.get(t) is not None]
        q1 = now + rng.uniform(0, 50)
        q2 = q1 + rng.uniform(0.01, 25)
        assert new.max_usage(q1, q2) == ref.max_usage(q1, q2)
        assert new.free_cores(q1, q2) == ref.free_cores(q1, q2)
        for cores in (1, 2, 4):
            assert new.fits(q1, q2, cores) == ref.fits(q1, q2, cores)
        assert new.load(q1, q2) == pytest.approx(ref.load(q1, q2), abs=1e-6)
        assert new.completion_times(q1, q2) == ref.completion_times(q1, q2)
        dur = rng.uniform(0.05, 8)
        cores = rng.choice([1, 2, 4])
        assert new.earliest_fit(dur, q1, cores) == pytest.approx(
            oracle.first_fit(dur, q1, 4 - cores), abs=0.0)
        assert len(new) == len(ref)


@pytest.mark.parametrize("seed", range(25 * FUZZ_SCALE))
def test_link_calendar_fuzz(seed):
    """Link fuzz with reserve-then-cancel churn (exercises the mutation-log
    annihilation path) on top of the usual earliest-slot agreement."""
    rng = random.Random(33_000 + seed)
    new = LinkCalendar()
    ref = ReferenceLinkCalendar()
    pairs = []
    now = 0.0
    for op in range(120):
        c = rng.random()
        if c < 0.45 or not pairs:
            dur = rng.uniform(0.005, 3.0)
            nb = now + rng.uniform(0, 25)
            a = new.reserve_earliest(dur, nb, op)
            b = ref.reserve_earliest(dur, nb, op)
            assert a.t1 == b.t1 and a.t2 == b.t2
            if rng.random() < 0.3:            # immediate rollback: the
                new.cancel(a)                  # delta annihilates in-log
                ref.cancel(b)
            else:
                pairs.append((a, b))
        elif c < 0.65:
            a, b = pairs.pop(rng.randrange(len(pairs)))
            new.cancel(a)
            ref.cancel(b)
        elif c < 0.80:
            now += rng.uniform(0, 8)
            new.gc(now)
            ref.gc(now)
            pairs = [(a, b) for a, b in pairs if b.t2 > now]
        q = now + rng.uniform(0, 35)
        dur = rng.uniform(0.005, 4.0)
        assert new.earliest_slot(dur, q) == ref.earliest_slot(dur, q)
        assert len(new) == len(ref)


@pytest.mark.parametrize("seed", range(15 * FUZZ_SCALE))
def test_probe_plane_fuzz_vs_scalar(seed):
    """The vectorized probe plane must answer bit-identically to the
    per-device scalar queries under random mutation/gc interleavings."""
    rng = random.Random(91_000 + seed)
    n_dev = rng.randint(2, 9)
    state = NetworkState(n_dev)
    now = 0.0
    live = []
    for op in range(100):
        c = rng.random()
        if c < 0.55 or not live:
            d = rng.randrange(n_dev)
            t1 = now + rng.uniform(0, 30)
            dur = rng.uniform(0.05, 10)
            cores = rng.choice([1, 2, 4])
            state.devices[d].reserve(t1, t1 + dur, cores, (seed, op))
            live.append((d, (seed, op)))
        elif c < 0.70:
            d, tag = live.pop(rng.randrange(len(live)))
            state.devices[d].release(tag)
        elif c < 0.80:
            now += rng.uniform(0, 8)
            state.gc(now)
            live = [(d, tag) for d, tag in live
                    if state.devices[d].get(tag) is not None]
        if rng.random() < 0.5:
            continue                          # stale plane rows next round
        plane = state.probe_plane()
        a = now + rng.uniform(0, 40)
        b = a + rng.uniform(0.01, 20)
        fits2 = plane.fits_mask(a, b, 2)
        free = plane.free_cores(a, b)
        loads = plane.loads(a, b)
        dur = rng.uniform(0.05, 8)
        cores = rng.choice([1, 2, 4])
        starts = plane.earliest_fit(dur, max(a, now), cores)
        for d, dev in enumerate(state.devices):
            assert bool(fits2[d]) == dev.fits(a, b, 2)
            assert int(free[d]) == dev.free_cores(a, b)
            assert float(loads[d]) == pytest.approx(dev.load(a, b), abs=1e-9)
            assert float(starts[d]) == dev.earliest_fit(dur, max(a, now),
                                                        cores)
        window = state.probe_plane(a, b)
        assert (window.fits(2) == fits2).all()
        assert (window.free_cores == free).all()
