"""Shared combo-building logic for the dry-run and roofline benchmarks.

``lower_combo`` builds the jitted step for one (arch x input-shape x mesh)
with baseline (or overridden) sharding rules, lowers it against
ShapeDtypeStruct stand-ins (no allocation) and returns the Lowered object
plus bookkeeping.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..configs.shapes import SHAPES, InputShape
from ..data.pipeline import input_specs, text_len
from ..models import model as M
from ..models.config import ModelConfig
from ..models.head_padding import pad_heads_config
from ..models.sharding import (
    RuleSet,
    batch_spec,
    cache_batch_rules,
    tree_shardings,
)
from ..training.optimizer import AdamWConfig, init_opt_state
from ..training.steps import make_prefill_step, make_serve_step, make_train_step


def adapt_config(cfg: ModelConfig, shape: InputShape,
                 dtype: str = "bfloat16") -> ModelConfig:
    """Apply the shape policy: long_500k switches attention archs to the
    sliding-window variant (sub-quadratic requirement, DESIGN.md §8.4)."""
    cfg = replace(cfg, param_dtype=dtype, activation_dtype=dtype)
    if shape.name == "long_500k" and cfg.uses_attention:
        cfg = cfg.with_sliding_window(cfg.long_context_window)
    return cfg


def _batch_shardings(specs: dict, mesh: Mesh, cfg: ModelConfig,
                     shape: InputShape, ruleset: RuleSet):
    bspec = batch_spec(mesh, shape.global_batch,
                       text_len(cfg, shape), ruleset)
    out = {}
    for name, sds in specs.items():
        if sds.ndim == 0:
            out[name] = NamedSharding(mesh, P())
        else:
            dims = [bspec[0], bspec[1] if len(bspec) > 1 else None]
            dims += [None] * (sds.ndim - 2)
            out[name] = NamedSharding(mesh, P(*dims[: sds.ndim]))
    return out


@dataclass
class Combo:
    arch: str
    shape: InputShape
    cfg: ModelConfig
    lowered: Any
    chips: int
    kind: str


def decode_cache_len(cfg: ModelConfig, shape: InputShape) -> int:
    if cfg.sliding_window > 0:
        return min(cfg.sliding_window, shape.seq_len)
    return shape.seq_len


def lower_combo(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    *,
    dtype: str = "bfloat16",
    ruleset: Optional[RuleSet] = None,
    moe_group_size: int = 256,
    remat: bool = True,
    unroll: int | bool = 1,
    opt: Optional[AdamWConfig] = None,
    cfg_override: Optional[ModelConfig] = None,
    pad_heads: int = 0,
    cfg_updates: Optional[dict] = None,
) -> Combo:
    shape = SHAPES[shape_name]
    cfg = cfg_override or adapt_config(get_config(arch), shape, dtype)
    if pad_heads:
        cfg = pad_heads_config(cfg, pad_heads)   # §Perf head-padding variant
    if cfg_updates:
        cfg = replace(cfg, **cfg_updates)        # §Perf config knobs
    ruleset = ruleset or RuleSet()
    chips = mesh.devices.size

    params_abs = M.abstract_params(cfg)
    p_axes = M.params_axes(cfg)
    p_sh = tree_shardings(p_axes, params_abs, mesh, ruleset)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        opt = opt or AdamWConfig()
        opt_abs = jax.eval_shape(lambda p: init_opt_state(opt, p), params_abs)
        o_sh = {
            "m": tree_shardings(p_axes, opt_abs["m"], mesh, ruleset),
            "v": tree_shardings(p_axes, opt_abs["v"], mesh, ruleset),
            "step": NamedSharding(mesh, P()),
        }
        b_sh = _batch_shardings(specs, mesh, cfg, shape, ruleset)
        step = make_train_step(cfg, opt, remat=remat,
                               moe_group_size=moe_group_size, unroll=unroll)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_abs, opt_abs, specs)
    elif shape.kind == "prefill":
        b_sh = _batch_shardings(specs, mesh, cfg, shape, ruleset)
        step = make_prefill_step(cfg, cache_len=shape.seq_len,
                                 moe_group_size=moe_group_size,
                                 unroll=unroll)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(params_abs, specs)
    else:  # decode
        cache_len = decode_cache_len(cfg, shape)
        enc_len = shape.seq_len if cfg.is_encoder_decoder else 0
        caches_abs = M.abstract_caches(cfg, shape.global_batch, cache_len,
                                       enc_len)
        c_axes = M.caches_axes(cfg)
        # head-parallel cache sharding impossible => seq-shard on `model`
        # (§Perf): MLA's latent cache has no head axis at all; GQA caches
        # need kv_heads % model == 0.
        model_sz = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        prefer_seq = (cfg.mla is not None or cfg.n_kv_heads % model_sz != 0)
        c_rules = cache_batch_rules(mesh, shape.global_batch, ruleset,
                                    prefer_seq_shard=prefer_seq)
        c_sh = tree_shardings(c_axes, caches_abs, mesh, c_rules)
        tok_sh = NamedSharding(
            mesh, batch_spec(mesh, shape.global_batch, 1, ruleset))
        step = make_serve_step(cfg, moe_group_size=moe_group_size,
                               unroll=unroll)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
            out_shardings=(tok_sh, c_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_abs, caches_abs, specs["token"],
                               specs["pos"])
    return Combo(arch, shape, cfg, lowered, chips, shape.kind)
