"""Corpus: the streaming path must import without jax."""
import jax                                 # BAD: module-level
from jax.experimental import pallas        # BAD: module-level from-import

try:
    import jax.numpy as jnp                # BAD: try does not defer
except ImportError:
    jnp = None
