"""Corpus: bare-int pallas index — the exact PR 3 bug shape."""
from jax.experimental import pallas as pl


def kernel(q_ref, o_ref):
    row = pl.load(q_ref, (0, pl.ds(0, 4)))          # BAD: bare 0
    pl.store(o_ref, (pl.ds(0, 4), -1), row)         # BAD: bare -1
