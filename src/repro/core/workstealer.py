"""Workstealer baselines as registered ``SchedulingPolicy`` plugins.

Centralised (global job queue) and decentralised (per-device queues with
random polling) workstealing, with a processor-sharing execution model:

Workstealers perform no admission control: devices rashly execute
whatever they steal (paper §8 "rash task placement decisions").  Cores
are therefore *oversubscribed*, which the paper reports as middleware
+ concurrent-DNN degradation (11.611 s benchmarked tasks averaging
~14.5 s).  We model execution as processor sharing: each running task
progresses at rate cores * min(1, capacity/demand); HP tasks addition-
ally pay a GIL/middleware interference penalty when the device is
oversubscribed (the Python inference manager competes with TFLite
worker threads).

These policies set ``drives_execution = True``: they run their own
event-driven execution through the host dispatcher (event queue, shared
rng, noise model) and report outcomes via the dispatcher's uniform
accounting hooks (``lp_started`` / ``task_finished``), so their metrics
are directly comparable with the slot-based disciplines.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from .metrics import Metrics
from .network import NetworkConfig
from .policy import Decision, DecisionStatus, SchedulingPolicy, register_policy
from .task import LowPriorityRequest, Priority, Task, TaskState
from .victims import select_victim


class _Run:
    __slots__ = ("work", "cores")

    def __init__(self, work: float, cores: int) -> None:
        self.work = work        # remaining core-seconds
        self.cores = cores


class _WSDevice:
    __slots__ = ("idx", "capacity", "running", "queue", "last", "event",
                 "inflight")

    def __init__(self, idx: int, capacity: int = 4) -> None:
        self.idx = idx
        self.capacity = capacity
        self.running: dict[Task, _Run] = {}
        self.queue: deque[Task] = deque()
        self.last = 0.0          # last time `work` values were advanced
        self.event = None
        self.inflight = 0        # cores reserved by steals still in transfer

    @property
    def demand(self) -> int:
        return sum(r.cores for r in self.running.values())

    @property
    def committed(self) -> int:
        """Cores running or promised (blocks further steals)."""
        return self.demand + self.inflight

    def share(self) -> float:
        d = self.demand
        return 1.0 if d <= self.capacity else self.capacity / d


class WorkstealingPolicy(SchedulingPolicy):
    """Centralised (global queue) or decentralised (per-device, random polls)."""

    drives_execution = True

    # HP interference coefficient: rate *= 1/(1 + GIL_COEF * over/capacity)
    # when the device is oversubscribed (see module docstring).
    GIL_COEF = 0.6
    # Zombie grace: a late task keeps burning cores for this fraction of a
    # frame period past its deadline before the violation kill lands
    # (detection + violation message + manager teardown are not instant).
    # Calibrated against the paper's Fig 2a workstealer frame counts.
    KILL_GRACE = 1.0

    def __init__(self, n_devices: int, net: NetworkConfig, *,
                 central: bool, capacity: int = 4, preemption: bool = True,
                 metrics: Optional[Metrics] = None, **_ignored) -> None:
        self.central = central
        self.net = net
        self.preemption = preemption
        self.metrics = metrics if metrics is not None else Metrics()
        self.devices = [_WSDevice(d, capacity) for d in range(n_devices)]
        self.global_queue: deque[Task] = deque()
        self._preempt_pending: set[Task] = set()
        self._polling: set[int] = set()
        # Stacked committed-cores vector (the workstealer's probe plane):
        # kept in sync by every demand/in-flight change so ``_kick_all``
        # selects stealable devices with one vectorized compare instead of
        # a per-device Python sweep.  A device filtered out here is exactly
        # one whose ``_kick`` would be a no-op, so decisions are unchanged.
        self._committed = np.zeros(n_devices, dtype=np.int64)
        self._cap_arr = np.full(n_devices, capacity, dtype=np.int64)

    # -- processor-sharing core ------------------------------------------- #
    def _hp_penalty(self, dev: _WSDevice) -> float:
        over = max(0, dev.demand - dev.capacity)
        return 1.0 / (1.0 + self.GIL_COEF * over / dev.capacity)

    def _rate(self, dev: _WSDevice, task: Task, run: _Run) -> float:
        rate = run.cores * dev.share()
        if task.priority == Priority.HIGH:
            rate *= self._hp_penalty(dev)
        return rate

    def _advance(self, dev: _WSDevice) -> None:
        """Drain elapsed progress into every running task's `work`."""
        now = self.host.q.now
        dt = now - dev.last
        if dt > 0:
            for task, run in dev.running.items():
                run.work -= dt * self._rate(dev, task, run)
        dev.last = now

    def _reschedule(self, dev: _WSDevice) -> None:
        """(Re)arm the next-completion event after any demand change."""
        if dev.event is not None:
            dev.event.cancel()
            dev.event = None
        if not dev.running:
            return
        soonest = min(
            run.work / max(self._rate(dev, task, run), 1e-12)
            for task, run in dev.running.items()
        )
        dev.event = self.host.q.push(
            self.host.q.now + max(soonest, 0.0), lambda: self._on_finish(dev)
        )

    def _on_finish(self, dev: _WSDevice) -> None:
        dev.event = None
        self._advance(dev)
        done = [t for t, r in dev.running.items() if r.work <= 1e-6]
        for task in done:
            run = dev.running.pop(task)
            self._committed[dev.idx] -= run.cores
            self._complete(dev, task)
        self._kick(dev)
        self._kick_all()
        self._reschedule(dev)

    def _start(self, dev: _WSDevice, task: Task, cores: int) -> None:
        host = self.host
        self._advance(dev)
        task.device, task.cores = dev.idx, cores
        task.offloaded = task.offloaded or (
            task.priority == Priority.LOW and dev.idx != task.source_device
        )
        task.state = TaskState.RUNNING
        prof = self.net.profile(task.task_type)
        if task.priority == Priority.HIGH:
            base = prof.hp_exec
            sigma = host.hp_noise_sigma
        else:
            base = prof.lp_proc_time(cores)
            sigma = host.lp_noise_sigma
        work = base * cores
        if host.exec_noise:
            work = max(0.05, work + host.rng.gauss(0.0, sigma * cores))
        dev.running[task] = _Run(work, cores)
        self._committed[dev.idx] += cores
        # The inference manager terminates tasks that overrun their deadline
        # (paper §7.3 task-violation messages) — partial work is wasted.
        if task.priority == Priority.LOW:
            host.q.push(task.deadline + self.KILL_GRACE * self.net.frame_period,
                        lambda: self._kill_if_late(dev, task))
        self._reschedule(dev)

    def _kill_if_late(self, dev: _WSDevice, task: Task) -> None:
        if task not in dev.running:
            return
        self._advance(dev)
        run = dev.running.pop(task)
        self._committed[dev.idx] -= run.cores
        task.state = TaskState.FAILED
        if task in self._preempt_pending:
            # A re-stolen victim killed late: its reallocation failed to
            # produce an on-time completion — one terminal bucket only.
            self._preempt_pending.discard(task)
            self.metrics.realloc_failure += 1
        else:
            self.metrics.lp_failed_runtime += 1
            self.metrics.count_type(task.task_type, "lp_failed_runtime")
        self._kick(dev)
        self._kick_all()
        self._reschedule(dev)

    # -- decisions --------------------------------------------------------- #
    def decide_hp(self, task: Task, now: float) -> Decision:
        dev = self.devices[task.source_device]
        # Preemption: if starting the HP task would oversubscribe the device,
        # evict the running LP task with the farthest deadline (work lost) —
        # the same shared victim-scoring rule the calendar scheduler ranks
        # its conflict candidates with (core/victims.py).
        preempted: list[Task] = []
        if self.preemption and dev.demand + 1 > dev.capacity:
            victims = [t for t in dev.running if t.priority == Priority.LOW]
            if victims:
                victim = select_victim(victims, "farthest_deadline")
                self._preempt(dev, victim)
                preempted.append(victim)
        self._start(dev, task, cores=1)
        return Decision(DecisionStatus.ADMITTED, preempted=preempted)

    def decide_lp(self, request: LowPriorityRequest, now: float) -> Decision:
        for t in request.tasks:
            if self.central:
                self.global_queue.append(t)
            else:
                self.devices[request.source_device].queue.append(t)
        self._kick_all()
        return Decision(DecisionStatus.DEFERRED)

    # -- preemption -------------------------------------------------------- #
    def _preempt(self, dev: _WSDevice, victim: Task) -> None:
        self._advance(dev)
        run = dev.running.pop(victim)
        self._committed[dev.idx] -= run.cores
        victim.state = TaskState.PREEMPTED
        victim.preempt_count += 1
        m = self.metrics
        m.preemptions += 1
        m.preempted_by_cores[run.cores] += 1
        self._preempt_pending.add(victim)
        # re-queue for re-stealing (the workstealer's "reallocation");
        # all partial work is lost.
        if self.central:
            self.global_queue.appendleft(victim)
        else:
            self.devices[victim.source_device].queue.appendleft(victim)
        self._reschedule(dev)

    # -- completion -------------------------------------------------------- #
    def _complete(self, dev: _WSDevice, task: Task) -> None:
        late = self.host.q.now > task.deadline + 1e-9
        self.host.task_finished(task, late)
        # A finished task leaves preempt-pending either way: an on-time
        # finish is a reallocation success, a late one already lands in
        # lp_failed_runtime (leaving it pending would double-count it as a
        # realloc_failure at finalize).
        if task.priority == Priority.LOW and task in self._preempt_pending:
            self._preempt_pending.discard(task)
            if not late:
                self.metrics.realloc_success += 1

    # -- stealing ---------------------------------------------------------- #
    def _kick_all(self) -> None:
        # One vectorized pass over the committed-cores vector: only devices
        # with at least two uncommitted cores can steal, and ``_kick`` is a
        # complete no-op for every other device, so the filter is exact.
        devices = self.devices
        for i in np.flatnonzero(self._committed + 2 <= self._cap_arr):
            self._kick(devices[int(i)])

    def _kick(self, dev: _WSDevice) -> None:
        host, m = self.host, self.metrics
        # Steal while there are >= 2 uncommitted cores (running + in-flight,
        # HP included); stealing is myopic (grab 4 cores when fully idle,
        # else 2) and rash (no completion-feasibility check).
        while dev.committed + 2 <= dev.capacity:
            task, delay = self._acquire(dev)
            if task is None:
                break
            # Myopic core choice from the task's own benchmark profile:
            # max config when fully idle, min config otherwise (the paper's
            # (2, 4) world picks 4 / 2 exactly as before).
            opts = self.net.lp_core_options_for(task.task_type)
            cores = opts[-1] if dev.committed == 0 else opts[0]
            # Rash (paper §8): stealers start tasks with no *completion*
            # feasibility check — a task started with 5 s to its deadline
            # burns cores until the deadline kill. Only tasks already past
            # their deadline are dropped at steal time.
            if host.q.now + delay > task.deadline:
                task.state = TaskState.FAILED
                if task in self._preempt_pending:
                    self._preempt_pending.discard(task)
                    m.realloc_failure += 1
                else:
                    m.lp_failed_alloc += 1
                    m.count_type(task.task_type, "lp_failed_alloc")
                continue
            host.lp_started(task, cores, dev.idx != task.source_device)
            if delay > 0:
                dev.inflight += cores
                self._committed[dev.idx] += cores

                def arrive(d=dev, t=task, c=cores) -> None:
                    d.inflight -= c
                    self._committed[d.idx] -= c
                    self._start(d, t, c)

                host.q.push(host.q.now + delay, arrive)
            else:
                self._start(dev, task, cores)
        if (
            not self.central
            and dev.committed + 2 <= dev.capacity
            and dev.idx not in self._polling
            and any(d.queue for d in self.devices)
        ):
            # decentralised: retry polling while idle
            self._polling.add(dev.idx)

            def poll_again() -> None:
                self._polling.discard(dev.idx)
                self._kick(dev)

            host.q.push(host.q.now + 0.25, poll_again)

    def _acquire(self, dev: _WSDevice) -> tuple[Optional[Task], float]:
        net = self.net
        poll = 2 * net.slot(net.msg.state_update)
        if self.central:
            if self.global_queue:
                task = self.global_queue.popleft()
                delay = poll + (
                    net.input_transfer_slot(task.task_type)
                    if task.source_device != dev.idx
                    else 0.0
                )
                return task, delay
            return None, 0.0
        # decentralised: own queue first, then random polling order
        if dev.queue:
            return dev.queue.popleft(), 0.0
        order = [d for d in self.devices if d is not dev]
        self.host.rng.shuffle(order)
        delay = 0.0
        for other in order:
            delay += poll
            if other.queue:
                task = other.queue.popleft()
                return task, delay + net.input_transfer_slot(task.task_type)
        return None, delay

    def finalize(self, now: float) -> None:
        m = self.metrics
        # Victims still awaiting a re-steal: their reallocation never
        # happened.  Mark them terminal here (they also sit in a queue
        # below, which must NOT count them again into lp_failed_alloc).
        # Sorted by task id: set order over Tasks is an implementation
        # detail (task_id value hashing); settle in submission order.
        for task in sorted(self._preempt_pending, key=lambda t: t.task_id):
            task.state = TaskState.FAILED
            m.realloc_failure += 1
        self._preempt_pending.clear()
        for q in [self.global_queue] + [d.queue for d in self.devices]:
            for task in q:
                if task.state == TaskState.PENDING:
                    task.state = TaskState.FAILED
                    m.lp_failed_alloc += 1
                    m.count_type(task.task_type, "lp_failed_alloc")


@register_policy("central_ws")
class CentralWorkstealingPolicy(WorkstealingPolicy):
    """Centralised workstealer: one global job queue at the controller."""

    def __init__(self, n_devices: int, net: NetworkConfig, **kwargs) -> None:
        kwargs.pop("central", None)
        super().__init__(n_devices, net, central=True, **kwargs)


@register_policy("decentral_ws")
class DecentralWorkstealingPolicy(WorkstealingPolicy):
    """Decentralised workstealer: per-device queues, random polling."""

    def __init__(self, n_devices: int, net: NetworkConfig, **kwargs) -> None:
        kwargs.pop("central", None)
        super().__init__(n_devices, net, central=False, **kwargs)
