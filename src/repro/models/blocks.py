"""Layer dispatch (mixer + FFN + optional cross-attention) and the
scan-over-repeats stage machinery.

A stage's parameters are stacked along a leading ``layers`` axis and executed
with ``jax.lax.scan`` so the HLO is O(1) in depth.  Caches are stacked the
same way and threaded through the scan as per-iteration inputs/outputs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .config import LayerDef, ModelConfig, StageDef
from .layers import attention, ffn, mamba, mla, xlstm
from .layers.common import rmsnorm, rmsnorm_axes, rmsnorm_init


@dataclass
class LayerCtx:
    """Everything a layer needs besides params/x/cache."""

    cfg: ModelConfig
    positions: jax.Array                  # [T] absolute positions
    causal: bool = True
    window: int = 0                       # sliding window (0 = full)
    enc_out: Optional[jax.Array] = None   # encoder output for cross-attn
    decode: bool = False
    moe_group_size: int = 256
    inner_unroll: int | bool = 1          # unroll inner (chunk) scans too


# --------------------------------------------------------------------------- #
# Single layer                                                                #
# --------------------------------------------------------------------------- #

_MIXER_INIT = {
    "attn": attention.attn_init,
    "mla": mla.mla_init,
    "mamba": mamba.mamba_init,
    "mlstm": xlstm.mlstm_init,
    "slstm": xlstm.slstm_init,
}
_MIXER_AXES = {
    "attn": attention.attn_axes,
    "mla": mla.mla_axes,
    "mamba": mamba.mamba_axes,
    "mlstm": xlstm.mlstm_axes,
    "slstm": xlstm.slstm_axes,
}


def layer_init(key, ld: LayerDef, cfg: ModelConfig, dtype) -> dict:
    keys = jax.random.split(key, 4)
    p: dict = {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "mixer": _MIXER_INIT[ld.mixer](keys[0], cfg, dtype),
    }
    if ld.ffn != "none":
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        if ld.ffn == "dense":
            p["ffn"] = ffn.ffn_init(keys[1], cfg.d_model, cfg.d_ff, dtype)
        else:
            p["ffn"] = ffn.moe_init(keys[1], cfg, dtype)
    if ld.cross_attn:
        p["norm_x"] = rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attention.attn_init(keys[2], cfg, dtype)
    return p


def layer_axes(ld: LayerDef, cfg: ModelConfig) -> dict:
    a: dict = {
        "norm1": rmsnorm_axes(),
        "mixer": _MIXER_AXES[ld.mixer](cfg),
    }
    if ld.ffn != "none":
        a["norm2"] = rmsnorm_axes()
        a["ffn"] = ffn.ffn_axes() if ld.ffn == "dense" else ffn.moe_axes(cfg)
    if ld.cross_attn:
        a["norm_x"] = rmsnorm_axes()
        a["cross"] = attention.attn_axes(cfg)
    return a


def layer_cache_init(ld: LayerDef, cfg: ModelConfig, batch: int,
                     cache_len: int, dtype, enc_len: int = 0) -> dict:
    c: dict = {}
    if ld.mixer == "attn":
        c["self"] = attention.init_kv_cache(
            batch, cache_len, cfg.n_kv_heads, cfg.resolved_head_dim, dtype)
    elif ld.mixer == "mla":
        c["self"] = mla.init_mla_cache(batch, cache_len, cfg, dtype)
    elif ld.mixer == "mamba":
        c["self"] = mamba.init_mamba_cache(batch, cfg, dtype)
    elif ld.mixer == "mlstm":
        c["self"] = xlstm.init_mlstm_cache(batch, cfg, dtype)
    elif ld.mixer == "slstm":
        c["self"] = xlstm.init_slstm_cache(batch, cfg, dtype)
    if ld.cross_attn:
        hd = cfg.resolved_head_dim
        c["cross"] = {
            "k": jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), dtype),
        }
    return c


def layer_cache_axes(ld: LayerDef) -> dict:
    c: dict = {}
    if ld.mixer == "attn":
        c["self"] = attention.kv_cache_axes()
    elif ld.mixer == "mla":
        c["self"] = mla.mla_cache_axes()
    elif ld.mixer == "mamba":
        c["self"] = mamba.mamba_cache_axes()
    elif ld.mixer == "mlstm":
        c["self"] = xlstm.mlstm_cache_axes()
    elif ld.mixer == "slstm":
        c["self"] = xlstm.slstm_cache_axes()
    if ld.cross_attn:
        c["cross"] = {
            "k": ("batch", "cache", "kv_heads", "head_dim"),
            "v": ("batch", "cache", "kv_heads", "head_dim"),
        }
    return c


def layer_apply(
    params: dict,
    ld: LayerDef,
    x: jax.Array,
    ctx: LayerCtx,
    cache: Optional[dict] = None,
) -> tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    cfg = ctx.cfg
    aux = jnp.zeros((), jnp.float32)
    self_cache = cache.get("self") if cache else None
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)

    if ld.mixer == "attn":
        out, new_self = attention.attn_apply(
            params["mixer"], h, cfg, positions=ctx.positions, causal=ctx.causal,
            window=ctx.window, cache=self_cache, chunk=cfg.attn_chunk,
            inner_unroll=ctx.inner_unroll)
        out = attention.attn_out_project(params["mixer"], out)
    elif ld.mixer == "mla":
        out, new_self = mla.mla_apply(
            params["mixer"], h, cfg, positions=ctx.positions,
            window=ctx.window, cache=self_cache)
    elif ld.mixer == "mamba":
        out, new_self = mamba.mamba_apply(params["mixer"], h, cfg,
                                          cache=self_cache,
                                          unroll=ctx.inner_unroll)
    elif ld.mixer == "mlstm":
        out, new_self = xlstm.mlstm_apply(params["mixer"], h, cfg,
                                          cache=self_cache)
    elif ld.mixer == "slstm":
        out, new_self = xlstm.slstm_apply(params["mixer"], h, cfg,
                                          cache=self_cache)
    else:
        raise ValueError(ld.mixer)
    x = x + out

    if ld.cross_attn:
        assert ctx.enc_out is not None or (cache and "cross" in cache)
        hx = rmsnorm(params["norm_x"], x, cfg.norm_eps)
        if cache and "cross" in cache and ctx.enc_out is None:
            ckv = cache["cross"]
        else:
            ckv = attention.cross_kv(params["cross"], ctx.enc_out)
        x = x + attention.cross_attend(params["cross"], hx, ckv, cfg)
    else:
        ckv = None

    if ld.ffn != "none":
        h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if ld.ffn == "dense":
            x = x + ffn.ffn_apply(params["ffn"], h2)
        else:
            y, aux = ffn.moe_apply(params["ffn"], h2, cfg,
                                   group_size=ctx.moe_group_size)
            x = x + y

    new_cache: Optional[dict] = None
    if cache is not None:
        new_cache = {}
        if new_self is not None:
            new_cache["self"] = new_self
        elif self_cache is not None:
            new_cache["self"] = self_cache
        if ld.cross_attn:
            new_cache["cross"] = ckv if "cross" not in cache else cache["cross"]
    return x, new_cache, aux


# --------------------------------------------------------------------------- #
# Stage (scan over repeats)                                                   #
# --------------------------------------------------------------------------- #


def stage_init(key, stage: StageDef, cfg: ModelConfig, dtype) -> dict:
    """Stacked params: {'p0'..'pN': vmapped layer params [repeats, ...]}."""

    def one_repeat(k):
        ks = jax.random.split(k, len(stage.pattern))
        return {
            f"p{i}": layer_init(ks[i], ld, cfg, dtype)
            for i, ld in enumerate(stage.pattern)
        }

    keys = jax.random.split(key, stage.repeats)
    return jax.vmap(one_repeat)(keys)


def stage_axes(stage: StageDef, cfg: ModelConfig) -> dict:
    def prepend(tree):
        return jax.tree.map(lambda ax: ("layers",) + ax, tree,
                            is_leaf=lambda v: isinstance(v, tuple))

    return {
        f"p{i}": prepend(layer_axes(ld, cfg))
        for i, ld in enumerate(stage.pattern)
    }


def stage_cache_init(stage: StageDef, cfg: ModelConfig, batch: int,
                     cache_len: int, dtype, enc_len: int = 0) -> dict:
    def one(_):
        return {
            f"p{i}": layer_cache_init(ld, cfg, batch, cache_len, dtype, enc_len)
            for i, ld in enumerate(stage.pattern)
        }

    return jax.vmap(one)(jnp.arange(stage.repeats))


def stage_cache_axes(stage: StageDef) -> dict:
    def prepend(tree):
        return jax.tree.map(lambda ax: ("layers",) + ax, tree,
                            is_leaf=lambda v: isinstance(v, tuple))

    return {
        f"p{i}": prepend(layer_cache_axes(ld))
        for i, ld in enumerate(stage.pattern)
    }


def stage_apply(
    params: dict,
    stage: StageDef,
    x: jax.Array,
    ctx: LayerCtx,
    caches: Optional[dict] = None,
    remat: bool = False,
    unroll: int | bool = 1,
) -> tuple[jax.Array, Optional[dict], jax.Array]:
    """Scan over stage.repeats; inside, unroll the (short) pattern.

    ``unroll=True`` fully unrolls the repeat loop — used by the roofline
    analysis so cost_analysis counts every layer (XLA cost analysis counts a
    while-loop body once; see launch/hlo_analysis.py)."""

    def body(carry, xs):
        x, aux = carry
        p, cache = xs
        new_caches = {}
        for i, ld in enumerate(stage.pattern):
            ci = cache[f"p{i}"] if cache is not None else None
            x, nc, a = layer_apply(p[f"p{i}"], ld, x, ctx, ci)
            aux = aux + a
            if nc is not None:
                new_caches[f"p{i}"] = nc
        return (x, aux), (new_caches if new_caches else None)

    if remat:
        body = jax.checkpoint(body)

    aux0 = jnp.zeros((), jnp.float32)
    (x, aux), new_caches = jax.lax.scan(body, (x, aux0), (params, caches),
                                        unroll=unroll)
    return x, new_caches, aux
