"""Pallas TPU kernel: single-token GQA decode attention, blocked over the
KV cache (the serve_step hot loop; memory-bound — the kernel's job is to
stream K/V through VMEM exactly once).

Grid: (B, n_kv_blocks).  Each program streams one [bs, KV, D] cache block
and accumulates the online softmax for all H = KV*G query heads of its batch
element into the output block (revisited across the s-grid dimension —
Pallas guarantees sequential grid iteration on TPU, so the accumulator lives
in the output ref plus two scratch rows)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_scalar_ref, q_ref, k_ref, v_ref, slots_ref,
                   o_ref, m_ref, l_ref, acc_ref, *, window: int):
    s_idx = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # [KV, G, D]
    k = k_ref[0].astype(jnp.float32)                    # [bs, KV, D]
    v = v_ref[0].astype(jnp.float32)
    stored = slots_ref[0]                               # [bs]
    pos = pos_scalar_ref[0]
    kv, g, d = q.shape
    scale = d ** -0.5

    scores = jnp.einsum("kgd,skd->kgs", q, k) * scale   # [KV, G, bs]
    valid = (stored >= 0) & (stored <= pos)
    if window > 0:
        valid &= stored > pos - window
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, scores.max(axis=-1))
    p = jnp.exp(scores - m_new[..., None])
    alpha = jnp.exp(m_old - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "kgs,skd->kgd", p, v)
    m_ref[...] = m_new

    @pl.when(s_idx == ns - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("window", "block_s", "interpret"))
def decode_attention(
    q: jax.Array,               # [B, H, D]
    k_cache: jax.Array,         # [B, S, KV, D]
    v_cache: jax.Array,
    positions: jax.Array,       # [B, S] int32
    pos,                        # scalar int32
    *,
    window: int = 0,
    block_s: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    assert s % block_s == 0
    qg = q.reshape(b, kv, g, d)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))
    grid = (b, s // block_s)
    out = pl.pallas_call(
        partial(_decode_kernel, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1, kv, g, d), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, block_s, kv, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s, kv, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, kv, g, d), lambda i, j: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g, d), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qg, k_cache, v_cache, positions)
    return out.reshape(b, h, d)
