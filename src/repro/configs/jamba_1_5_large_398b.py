"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Super-block of 8 layers (attention at index 3, Mamba elsewhere; MoE on odd
indices, dense on even), repeated 9 times.  Attention layers use a sliding
window so long_500k decode state stays O(window).
"""
from __future__ import annotations

from dataclasses import replace

from ..models.config import LayerDef, MambaConfig, ModelConfig, MoEConfig, StageDef


def _superblock() -> tuple[LayerDef, ...]:
    return tuple(
        LayerDef(
            mixer="attn" if i == 3 else "mamba",
            ffn="moe" if i % 2 == 1 else "dense",
        )
        for i in range(8)
    )


CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    stages=(StageDef(_superblock(), 9),),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, n_shared=0),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2403.19887",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512,
        stages=(StageDef(
            (LayerDef("mamba", "dense"), LayerDef("attn", "moe"),
             LayerDef("mamba", "moe"), LayerDef("mamba", "dense")), 1),),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, n_shared=0),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
    )
