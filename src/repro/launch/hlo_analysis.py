"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
memory term     = HLO_bytes / (chips * HBM_bw)
collective term = collective_bytes / (chips * link_bw)

``cost_analysis`` provides FLOPs/bytes.  IMPORTANT (measured, see
EXPERIMENTS.md methodology): the compiled module is the per-device SPMD
program, so cost_analysis FLOPs/bytes are already per-chip — i.e. they equal
HLO_FLOPs/chips in the formulas above.  We therefore divide by the per-chip
peak only.  Equally important: XLA cost analysis counts a while-loop body
ONCE, so the layer scan must be lowered with unroll=True for roofline runs
(the plain dry-run keeps the scan for fast compile proofs).  Collective bytes are parsed from the
optimized HLO text (``compiled.as_text()``): for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction we
take its *result* shape and convert to per-link wire bytes with the standard
ring/bidirectional formulas (documented per-op below), using the replica
group size parsed from the instruction.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e hardware constants (per the brief).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# NOTE: tuple result types of fused collectives contain `/*index=N*/`
# comments (which include `=`), so the tuple branch must be `\([^)]*\)`
# (HLO shape tuples never nest parentheses) — an earlier `[^=]*?` version
# silently dropped every >5-element fused gradient all-reduce.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))          # [num_groups, group_size]
    m = _GROUPS_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _SRC_TGT_RE.search(line)
    if m:
        return 2
    return 2


@dataclass
class CollectiveStats:
    # wire bytes crossing links, per collective kind
    by_kind: dict = field(default_factory=dict)
    count: int = 0

    @property
    def total_bytes(self) -> float:
        return sum(self.by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum per-link wire bytes of every collective in the optimized HLO."""
    stats = CollectiveStats()
    seen_done: set = set()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m is None:
            continue
        # async pairs appear as -start/-done; count once (the -start)
        if "-done(" in line:
            continue
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        n = max(2, _group_size(line))
        if kind == "all-gather":
            wire = size * (n - 1) / n           # result is the gathered size
        elif kind == "all-reduce":
            wire = 2 * size * (n - 1) / n       # reduce-scatter + all-gather
        elif kind == "reduce-scatter":
            wire = size * (n - 1)               # result is the scattered size
        elif kind == "all-to-all":
            wire = size * (n - 1) / n
        else:                                   # collective-permute
            wire = size
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + wire
        stats.count += 1
    return stats


@dataclass
class Roofline:
    flops: float                 # per-device (SPMD module) FLOPs
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective wire bytes
    chips: int
    collectives: dict = field(default_factory=dict)
    n_collectives: int = 0
    model_flops: float = 0.0     # analytic 6ND-style global model FLOPs

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per-chip basis) — catches remat /
        dispatch / recompute waste.  < 1 means the compiled program does
        more raw FLOPs than the model math requires."""
        if not self.flops:
            return 0.0
        return (self.model_flops / self.chips) / self.flops

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def summary(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "n_collectives": self.n_collectives,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "collectives_by_kind": self.collectives,
        }


def roofline_from_compiled(compiled, chips: int,
                           hlo_text: str | None = None,
                           model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    stats = collective_bytes(text)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=stats.total_bytes,
        chips=chips,
        collectives=stats.by_kind,
        n_collectives=stats.count,
        model_flops=model_flops,
    )


def analytic_model_flops(cfg, shape) -> float:
    """MODEL_FLOPS per the brief: 6*N*D for training (N = active non-embed
    params), 2*N*D for prefill, 2*N per generated token for decode."""
    n_active = cfg.param_count(active_only=True)
    n_active -= cfg.padded_vocab * cfg.d_model 
    if not cfg.tie_embeddings:
        n_active -= cfg.padded_vocab * cfg.d_model
    n_active = max(n_active, 1)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # one token per sequence
